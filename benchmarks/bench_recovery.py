"""Durability benchmarks — snapshot, journal, recovery (DESIGN.md §2.13).

Three benches over the session durability stack, written to
``BENCH_pr10.json`` (``--quick`` -> ``BENCH_pr10.quick.json``):

- ``journal``: append throughput (records/s and ops/s) per fsync policy
  ("always" pays an fsync per commit; "batch" flushes to the OS;
  "never" buffers) for batches of ``ops_per_record`` edge ops.
- ``snapshot``: ``session.save()`` wall time and on-disk byte size, with
  a warm query cache (the snapshot includes the cached fixed points).
- ``recovery``: ``DiffusionSession.open()`` wall time — snapshot load +
  replay of ``k`` journaled commits — against the cold-rebuild baseline
  (from_edges + partition + fresh queries).  Asserts the recovered SSSP
  values are bitwise-equal to the uninterrupted session's.

Run: ``python benchmarks/bench_recovery.py [--quick]``
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.core.journal import OpRecord, UpdateJournal
from repro.core.session import DiffusionSession

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _edges(n: int, m: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int64)
    dst = rng.integers(0, n, m).astype(np.int64)
    keep = src != dst
    w = rng.uniform(0.5, 2.0, m).astype(np.float32)[keep]
    return src[keep], dst[keep], w


def _build(src, dst, w, n, n_cells):
    return DiffusionSession.from_edges(
        src, dst, n, w, n_cells=n_cells, edge_slack=0.5, node_slack=0.5)


def _dir_bytes(d: str) -> int:
    total = 0
    for root, _, files in os.walk(d):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


# ---------------------------------------------------------------------------


def bench_journal(records: int, ops_per_record: int, n: int) -> list[dict]:
    rows = []
    rng = np.random.default_rng(7)
    eadds = [(int(rng.integers(0, n)), int(rng.integers(0, n)), 1.0)
             for _ in range(ops_per_record)]
    rec = OpRecord.from_ops([], [], eadds, [], [])
    for fsync in ("always", "batch", "never"):
        d = tempfile.mkdtemp(prefix="bench_journal_")
        try:
            j = UpdateJournal(os.path.join(d, "journal.bin"), fsync=fsync)
            t0 = time.perf_counter()
            for _ in range(records):
                j.append(rec)
            j.close()
            dt = time.perf_counter() - t0
            rows.append(dict(
                bench="journal", fsync=fsync, records=records,
                ops_per_record=ops_per_record, seconds=dt,
                records_per_s=records / dt,
                ops_per_s=records * ops_per_record / dt,
                bytes=os.path.getsize(os.path.join(d, "journal.bin"))))
        finally:
            shutil.rmtree(d, ignore_errors=True)
    return rows


def bench_snapshot(n: int, m: int, n_cells: int, reps: int) -> list[dict]:
    src, dst, w = _edges(n, m)
    sess = _build(src, dst, w, n, n_cells)
    sess.query("sssp", source=0)
    sess.query("cc")
    best = np.inf
    d = tempfile.mkdtemp(prefix="bench_snap_")
    try:
        for _ in range(reps):
            t0 = time.perf_counter()
            sess.save(d)
            best = min(best, time.perf_counter() - t0)
        size = _dir_bytes(d)
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return [dict(bench="snapshot", n=n, m=m, cells=n_cells,
                 seconds=best, bytes=size,
                 mb_per_s=size / best / 1e6)]


def bench_recovery(n: int, m: int, n_cells: int, k_commits: int) -> list[dict]:
    src, dst, w = _edges(n, m)
    d = tempfile.mkdtemp(prefix="bench_recover_")
    rng = np.random.default_rng(11)
    try:
        sess = _build(src, dst, w, n, n_cells)
        sess.query("sssp", source=0)
        sess.save(d)
        for _ in range(k_commits):
            sess.add_edge(int(rng.integers(0, n)),
                          int(rng.integers(0, n)), 0.75)
            sess.commit()
        ref = np.asarray(sess.query("sssp", source=0).values)

        t0 = time.perf_counter()
        recovered = DiffusionSession.open(d)
        t_open = time.perf_counter() - t0
        got = np.asarray(recovered.query("sssp", source=0).values)
        assert np.array_equal(ref, got, equal_nan=True), (
            "recovered SSSP diverges from the uninterrupted session")

        t0 = time.perf_counter()
        cold = _build(src, dst, w, n, n_cells)
        cold.query("sssp", source=0)
        t_cold = time.perf_counter() - t0
        return [dict(bench="recovery", n=n, m=m, cells=n_cells,
                     journal_records=k_commits, open_s=t_open,
                     cold_rebuild_s=t_cold,
                     speedup_vs_rebuild=t_cold / t_open)]
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------


def run(quick: bool = False) -> list[dict]:
    if quick:
        n, m, cells, k = 5_000, 20_000, 4, 8
        records, ops = 200, 64
        reps = 1
    else:
        n, m, cells, k = 100_000, 400_000, 16, 64
        records, ops = 2_000, 256
        reps = 3
    rows = []
    rows += bench_journal(records, ops, n)
    rows += bench_snapshot(n, m, cells, reps)
    rows += bench_recovery(n, m, cells, k)
    return rows


def main():
    quick = "--quick" in sys.argv
    rows = run(quick=quick)
    for r in rows:
        print(r)
    fname = "BENCH_pr10.quick.json" if quick else "BENCH_pr10.json"
    with open(os.path.join(REPO, fname), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {fname} ({len(rows)} records)")
    return rows


if __name__ == "__main__":
    main()
