"""Commit-path benchmarks (DESIGN.md §2.9, BENCH_pr5.json).

Three measurements around the O(batch) incremental commit:

* ``bench_apply``  — the headline: ``UpdateBatch.apply`` latency vs
  batch size and graph size, incremental (tombstones + staged delta
  blocks, one compiled scatter program) vs the eager ``with_csr``
  rebuild (two stable argsorts of the whole edge stream + a host-synced
  free-slot loop).  The acceptance bar: a <= 64-edge batch on
  scale-free n=3000 commits >= 5x faster incrementally.
* ``bench_e2e``    — end-to-end update -> repair -> query: a session
  holding a warm SSSP fixed point absorbs a small insert batch and
  serves a fresh answer; incremental apply vs forced-eager apply, same
  push-sweep repair either way.
* ``bench_dirty_sweep`` — what the delta segment costs readers: one
  dense relaxation sweep on a clean graph vs the same graph carrying a
  staged delta segment + tombstones (the ~25%-bounded overhead the
  compaction policy enforces).

Timings are best-of-N on whatever backend JAX picks (CPU in CI); the
derived speedups — not absolute times — are the tracked quantities.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import build
from repro.core.diffuse import diffuse, diffuse_from
from repro.core.dynamic import NameServer
from repro.core.generators import make_graph_family
from repro.core.programs import sssp_program
from repro.core.updates import UpdateBatch


def _best_of(fn, repeats: int) -> float:
    import jax

    jax.block_until_ready(fn())          # warm the jit cache
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _graph(n_nodes: int, n_cells: int, seed: int = 0):
    src, dst, w, n = make_graph_family("scale_free", n_nodes, seed=seed)
    return build(src, dst, n, w, n_cells=n_cells, edge_slack=0.2,
                 node_slack=0.1), n


def bench_apply(n_nodes: int = 3000, n_cells: int = 2, seed: int = 0,
                repeats: int = 5, batch_sizes=(8, 64, 256)):
    """UpdateBatch.apply latency, incremental vs eager rebuild, per
    batch size (mixed insert-heavy traffic with a few deletes — the
    paper's streaming shape).  Applies are functional and discard the
    result, so every repeat sees the identical graph."""
    part, n = _graph(n_nodes, n_cells, seed)
    ns = NameServer(part)
    rng = np.random.default_rng(seed + 1)
    src_e, dst_e, _, _ = make_graph_family("scale_free", n_nodes,
                                           seed=seed)
    rows = []
    for bsz in batch_sizes:
        n_del = max(1, bsz // 8)
        ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
                float(0.2 + rng.random())) for _ in range(bsz - n_del)]
        dels = [(int(src_e[i]), int(dst_e[i]))
                for i in rng.choice(len(src_e), n_del, replace=False)]

        def mk():
            ub = UpdateBatch(ns)
            for u, v, x in ins:
                ub.add_edge(u, v, x)
            for u, v in dels:
                ub.delete_edge(u, v)
            return ub

        t_inc = _best_of(lambda: mk().apply(part.sg)[0].csr_perm, repeats)
        t_eager = _best_of(
            lambda: mk().apply(part.sg, incremental=False)[0].csr_perm,
            repeats)
        rows.append(dict(
            bench="apply", n_nodes=n_nodes, batch=bsz,
            inc_us=t_inc * 1e6, eager_us=t_eager * 1e6,
            speedup_vs_eager=t_eager / t_inc,
        ))
    return rows


def bench_e2e(n_nodes: int = 3000, n_cells: int = 2, n_updates: int = 8,
              seed: int = 0, repeats: int = 5):
    """update -> repair -> query: apply a small insert batch and repair
    the cached SSSP fixed point from the insert frontier (push sweep —
    the PR 4 path), comparing the incremental apply against the forced
    eager rebuild on the same repair."""
    import jax
    import jax.numpy as jnp

    part, n = _graph(n_nodes, n_cells, seed)
    ns = NameServer(part)
    prog = sssp_program(0)
    vstate, _ = diffuse(part, prog)
    rng = np.random.default_rng(seed + 2)
    ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
            float(0.2 + rng.random())) for _ in range(n_updates)]
    owner = np.asarray(part.owner)
    local = np.asarray(part.local)
    active = np.zeros((part.sg.n_shards, part.sg.n_per_shard), bool)
    for u, _, _ in ins:
        active[owner[u], local[u]] = True
    active = jnp.asarray(active)

    def run(incremental: bool):
        ub = UpdateBatch(ns)
        for u, v, x in ins:
            ub.add_edge(u, v, x)
        sg2, _ = ub.apply(part.sg, incremental=incremental)
        vs, _ = diffuse_from(sg2, prog, vstate, active, sweep="push")
        return vs["dist"]

    t_inc = _best_of(lambda: run(True), repeats)
    t_eager = _best_of(lambda: run(False), repeats)
    return [dict(
        bench="e2e", n_nodes=n_nodes, n_updates=n_updates,
        inc_s=t_inc, eager_s=t_eager, speedup_vs_eager=t_eager / t_inc,
    )]


def bench_dirty_sweep(n_nodes: int = 3000, n_cells: int = 2, seed: int = 0,
                      repeats: int = 5, n_staged: int = 32):
    """Reader-side cost of the delta segment: a full SSSP diffusion on
    the clean graph vs the same graph carrying staged adds + tombstones
    (bounded by the compaction policy at ~25% extra stream)."""
    part, n = _graph(n_nodes, n_cells, seed)
    ns = NameServer(part)
    prog = sssp_program(0)
    rng = np.random.default_rng(seed + 3)
    ub = UpdateBatch(ns)
    for _ in range(n_staged):
        ub.add_edge(int(rng.integers(0, n)), int(rng.integers(0, n)),
                    float(0.2 + rng.random()))
    sg_dirty, _ = ub.apply(part.sg)
    t_clean = _best_of(lambda: diffuse(part.sg, prog)[0]["dist"], repeats)
    t_dirty = _best_of(lambda: diffuse(sg_dirty, prog)[0]["dist"], repeats)
    return [dict(
        bench="dirty_sweep", n_nodes=n_nodes, n_staged=n_staged,
        clean_s=t_clean, dirty_s=t_dirty,
        overhead=t_dirty / t_clean - 1.0,
    )]


def run(quick: bool = False):
    size = 800 if quick else 3000
    reps = 3 if quick else 5
    batches = (8, 64) if quick else (8, 64, 256)
    rows = []
    rows += bench_apply(n_nodes=size, repeats=reps, batch_sizes=batches)
    rows += bench_e2e(n_nodes=size, repeats=reps)
    rows += bench_dirty_sweep(n_nodes=size, repeats=reps)
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
