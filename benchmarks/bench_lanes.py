"""Lane-scaling microbenchmark: B personalized queries (PPR forward push)
served as B lanes of one diffusion vs B sequential single-source queries
(DESIGN.md §2.7).

Three numbers per batch size:

* ``round_ratio`` — engine work: total global exchange rounds summed
  over B sequential fixed points vs the single laned fixed point (which
  runs max-over-lanes rounds).  This is the "one sweep answers B
  queries" property (DESIGN.md §2.7), independent of host/compile
  overheads, and the serving-cost metric the ROADMAP's "millions of
  users" scenario cares about.
* ``speedup_cold`` — end-to-end wall-clock including program build + jit
  compilation, fresh sessions.  The single-source API bakes the source
  into the program, but since the init-excluding program identity
  (DESIGN.md §2.11) B distinct sources share one ``_run_rounds``
  compilation in *both* arms, so this no longer measures compile
  amortization (it was ~16x back when sequential paid B compiles) and
  now hovers near parity on CPU; kept as a wall-clock regression guard.
* ``speedup_warm`` — steady-state recompute (refresh=True on already-built
  programs): the pure engine-side effect of sharing one sweep.  On CPU
  this sits near/below 1 at larger graphs (the segmented scan is
  memory-bound, so B lanes move ~B× the stream traffic while iterating
  the union of the lanes' frontier schedules); it is reported for
  transparency.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import DiffusionSession
from repro.core.generators import make_graph_family


def bench_lane_batch(n_nodes: int = 1500, batch: int = 32, seed: int = 0,
                     n_cells: int = 4, prog: str = "ppr",
                     repeats: int = 2, eps: float = 1e-4):
    """One (batch size) measurement row; see module docstring."""
    src, dst, w, n = make_graph_family("scale_free", n_nodes, seed=seed)
    rng = np.random.default_rng(seed)
    sources = [int(s) for s in rng.choice(n, batch, replace=False)]

    def fresh():
        return DiffusionSession.from_edges(src, dst, n, w, n_cells=n_cells)

    # ---- cold: program build + compile + run, fresh sessions ----
    sess_seq = fresh()
    t0 = time.perf_counter()
    for s in sources:
        sess_seq.query(prog, source=s, eps=eps)
    t_seq_cold = time.perf_counter() - t0

    sess_bat = fresh()
    t0 = time.perf_counter()
    batch_res = sess_bat.query(prog, sources=sources, eps=eps)
    t_bat_cold = time.perf_counter() - t0

    # lanes must reproduce the sequential fixed points bitwise; tally
    # the engine work while we're at it (every lane result shares the
    # one laned DiffuseStats)
    seq_rounds = seq_iters = 0
    for s, r in zip(sources, batch_res):
        ref = sess_seq.query(prog, source=s, eps=eps)   # cache hit
        assert np.array_equal(r.values, ref.values), s
        seq_rounds += int(ref.stats.rounds)
        seq_iters += int(ref.stats.local_iters)
    bat_rounds = int(batch_res[0].stats.rounds)
    bat_iters = int(batch_res[0].stats.local_iters)

    # ---- warm: steady-state recompute on built programs ----
    def best_of(fn):
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_seq_warm = best_of(lambda: [sess_seq.query(prog, source=s, eps=eps,
                                                 refresh=True)
                                  for s in sources])
    t_bat_warm = best_of(lambda: sess_bat.query(prog, sources=sources,
                                                eps=eps, refresh=True))

    return dict(
        bench="lanes", prog=prog, batch=batch, n_nodes=n_nodes,
        n_cells=n_cells,
        sequential_rounds=seq_rounds, batched_rounds=bat_rounds,
        round_ratio=seq_rounds / bat_rounds,
        sequential_local_iters=seq_iters, batched_local_iters=bat_iters,
        sequential_cold_s=t_seq_cold, batched_cold_s=t_bat_cold,
        speedup_cold=t_seq_cold / t_bat_cold,
        sequential_warm_s=t_seq_warm, batched_warm_s=t_bat_warm,
        speedup_warm=t_seq_warm / t_bat_warm,
    )


def run(batch_sizes=(1, 2, 4, 8, 16, 32, 64), n_nodes: int = 1500,
        quick: bool = False):
    if quick:
        batch_sizes, n_nodes = (1, 4, 8), 400
    return [bench_lane_batch(n_nodes=n_nodes, batch=b) for b in batch_sizes]


def main():
    rows = run()
    print(f"{'B':>4s} {'rounds':>9s} {'x rnds':>7s} "
          f"{'seq cold':>10s} {'bat cold':>10s} {'x cold':>7s} "
          f"{'seq warm':>10s} {'bat warm':>10s} {'x warm':>7s}")
    for r in rows:
        print(f"{r['batch']:4d} "
              f"{r['sequential_rounds']:4d}/{r['batched_rounds']:<4d} "
              f"{r['round_ratio']:6.1f}x "
              f"{r['sequential_cold_s']*1e3:9.1f}ms "
              f"{r['batched_cold_s']*1e3:9.1f}ms {r['speedup_cold']:6.1f}x "
              f"{r['sequential_warm_s']*1e3:9.1f}ms "
              f"{r['batched_warm_s']*1e3:9.1f}ms {r['speedup_warm']:6.1f}x")
    return rows


if __name__ == "__main__":
    main()
