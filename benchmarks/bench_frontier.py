"""Direction-optimizing sweep benchmarks (DESIGN.md §2.8, BENCH_pr4.json).

Three measurements, each push vs pull on the *same* graph:

* ``bench_repair``    — the headline: commit()-style warm repair after a
  small UpdateBatch (insert endpoints = the frontier), timed as the
  repair diffusion itself.  This is the sparse-frontier scenario the
  push sweep exists for: O(frontier-adjacent edges) per round instead of
  O(E).
* ``bench_density``   — one relaxation sweep at controlled frontier
  densities: where the push/pull crossover sits, which is what the
  ``push_threshold`` selector knob is tuned from (together with the
  per-round ``frontier_log``/``dir_log`` stats).
* ``bench_sssp_tail`` — end-to-end delta-stepped SSSP, whose bucketed
  tail rounds are exactly the sparse wavefronts the auto selector should
  win on.

Timings are best-of-N on whatever backend JAX picks (CPU in CI); the
derived speedups — not absolute times — are the tracked quantities.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import build
from repro.core.diffuse import _sg_as_dict, diffuse, diffuse_from
from repro.core.dynamic import NameServer
from repro.core.generators import make_graph_family
from repro.core.programs import sssp_program
from repro.core.relax import active_push_blocks, make_relax, select_bucket
from repro.core.updates import UpdateBatch


def _best_of(fn, repeats: int) -> float:
    import jax

    jax.block_until_ready(fn())          # warm the jit cache
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _graph(n_nodes: int, n_cells: int, seed: int = 0):
    src, dst, w, n = make_graph_family("scale_free", n_nodes, seed=seed)
    return build(src, dst, n, w, n_cells=n_cells, edge_slack=0.2,
                 node_slack=0.1), n


def bench_repair(n_nodes: int = 3000, n_cells: int = 2, n_updates: int = 8,
                 seed: int = 0, repeats: int = 5):
    """commit()-repair cost after a small insert-only UpdateBatch: the
    warm frontier re-diffusion (the session's 'frontier' strategy core)
    per sweep direction.  Returns one row per sweep with the speedup of
    that sweep over the dense pull baseline."""
    import jax.numpy as jnp

    part, n = _graph(n_nodes, n_cells, seed)
    prog = sssp_program(0)
    vstate, _ = diffuse(part, prog)                 # the cached fixed point

    rng = np.random.default_rng(seed + 1)
    ns = NameServer(part)
    ub = UpdateBatch(ns)
    ins = [(int(rng.integers(0, n)), int(rng.integers(0, n)),
            float(0.2 + rng.random())) for _ in range(n_updates)]
    for u, v, w in ins:
        ub.add_edge(u, v, w)
    sg2, _ = ub.apply(part.sg)

    owner = np.asarray(part.owner)
    local = np.asarray(part.local)
    active = np.zeros((sg2.n_shards, sg2.n_per_shard), bool)
    for u, _, _ in ins:                             # the repair frontier
        active[owner[u], local[u]] = True
    active = jnp.asarray(active)

    times = {}
    for sweep in ("pull", "push", "auto"):
        times[sweep] = _best_of(
            lambda sw=sweep: diffuse_from(sg2, prog, vstate, active,
                                          sweep=sw),
            repeats)
    _, st = diffuse_from(sg2, prog, vstate, active, sweep="push")
    rows = []
    for sweep in ("pull", "push", "auto"):
        rows.append(dict(
            bench="repair", sweep=sweep, n_nodes=n_nodes,
            n_updates=n_updates, seconds=times[sweep],
            speedup_vs_pull=times["pull"] / times[sweep],
            repair_rounds=int(st.rounds),
        ))
    return rows


def bench_density(n_nodes: int = 3000, n_cells: int = 2, seed: int = 0,
                  repeats: int = 5,
                  densities=(1 / 256, 1 / 64, 1 / 16, 1 / 4, 1.0)):
    """One relaxation sweep (the engine's inner hot op) at controlled
    frontier densities: frontier vertices drawn contiguously in the
    source order (the locality a real wavefront has), push vs pull."""
    import jax
    import jax.numpy as jnp

    part, n = _graph(n_nodes, n_cells, seed)
    sg = part.sg
    sgd = _sg_as_dict(sg, with_push=True)
    prog = sssp_program(0)
    vstate, _ = prog.init(sg)
    block = sg.csr_block
    nb = sgd["push_src"].shape[-1] // block

    relax_pull = make_relax(prog, sg.n_shards, sg.n_per_shard, block,
                            sweep="pull")
    relax_push = make_relax(prog, sg.n_shards, sg.n_per_shard, block,
                            sweep="push")

    @jax.jit
    def step_pull(vs, senders):
        return jax.vmap(lambda v, s, g: relax_pull(v, s, g))(
            vs, senders, sgd)

    @jax.jit
    def step_push(vs, senders):
        counts = active_push_blocks(senders, sgd["push_src"], block)
        bucket = select_bucket(counts, nb, "push")   # selector cost incl.
        return jax.vmap(lambda v, s, g: relax_push(v, s, g, bucket))(
            vs, senders, sgd)

    rows = []
    rng = np.random.default_rng(seed + 2)
    for d in densities:
        k = max(1, int(d * sg.n_per_shard))
        senders = np.zeros((sg.n_shards, sg.n_per_shard), bool)
        for s in range(sg.n_shards):
            start = int(rng.integers(0, max(1, sg.n_per_shard - k)))
            senders[s, start:start + k] = True
        senders = jnp.asarray(senders & np.asarray(sg.node_ok))
        t_pull = _best_of(lambda: step_pull(vstate, senders), repeats)
        t_push = _best_of(lambda: step_push(vstate, senders), repeats)
        rows.append(dict(
            bench="density", density=float(d),
            frontier=int(np.asarray(senders).sum()),
            pull_us=t_pull * 1e6, push_us=t_push * 1e6,
            speedup_vs_pull=t_pull / t_push,
        ))
    return rows


def bench_sssp_tail(n_nodes: int = 3000, n_cells: int = 2, seed: int = 0,
                    repeats: int = 3, delta: float = 0.5):
    """End-to-end delta-stepped SSSP: the bucketed tail rounds run tiny
    frontiers, so the auto selector should beat pure pull there while a
    dense early wave keeps pure push honest."""
    part, _ = _graph(n_nodes, n_cells, seed)
    prog = sssp_program(0)
    times = {}
    for sweep in ("pull", "push", "auto"):
        times[sweep] = _best_of(
            lambda sw=sweep: diffuse(part, prog, delta=delta, sweep=sw),
            repeats)
    _, st = diffuse(part, prog, delta=delta, sweep="auto")
    push_share = int(st.push_iters) / max(int(st.local_iters), 1)
    rows = []
    for sweep in ("pull", "push", "auto"):
        rows.append(dict(
            bench="sssp_tail", sweep=sweep, n_nodes=n_nodes, delta=delta,
            seconds=times[sweep],
            speedup_vs_pull=times["pull"] / times[sweep],
            auto_push_share=push_share,
        ))
    return rows


def run(quick: bool = False):
    size = 800 if quick else 3000
    reps = 3 if quick else 5
    rows = []
    rows += bench_repair(n_nodes=size, repeats=reps)
    rows += bench_density(n_nodes=size, repeats=reps)
    rows += bench_sssp_tail(n_nodes=size, repeats=reps)
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
