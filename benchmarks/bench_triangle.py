"""Paper Table III + Figs 8-10: triangle counting and the CCA hops model.

Reproduces the paper's speculative analysis on its published dataset counts
(Twitter / WDC-2012 / Graph500-s24) AND re-derives the same table from
graphs we generate + count ourselves (exact + bitset counters).
"""

from __future__ import annotations

import numpy as np

from repro.core.generators import make_graph_family
from repro.core.triangles import (
    PAPER_TABLE_III,
    cca_cost_model,
    triangle_count_bitset,
    triangle_count_exact,
    wedge_count,
)


def _bitset_chunked(src, dst, n: int, chunk: int = 1 << 16) -> int:
    """Edge-chunked variant of :func:`triangle_count_bitset` so the
    [E, lanes] intersection buffer stays bounded at larger n."""
    import jax.numpy as jnp

    lanes = -(-n // 32)
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    flat = src * lanes + (dst // 32).astype(jnp.int32)
    vals = jnp.left_shift(jnp.uint32(1), (dst % 32).astype(jnp.uint32))
    rows = jnp.zeros((n * lanes,), jnp.uint32).at[flat].add(vals)
    rows = rows.reshape(n, lanes)
    total = 0
    for lo in range(0, int(src.shape[0]), chunk):
        x = rows[src[lo:lo + chunk]] & rows[dst[lo:lo + chunk]]
        x = x - ((x >> 1) & jnp.uint32(0x55555555))
        x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
        x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
        pc = (x * jnp.uint32(0x01010101)) >> 24
        # chunk popcount total <= chunk * 32 * lanes ~ 1e9: fits uint32
        total += int(pc.sum())
    return total // 6


def run(n_nodes: int = 1200, seed: int = 0, big_nodes: int = 16384):
    rows = []
    # the paper's published counts for *external* datasets we do not have
    # (Twitter, WDC-2012) -> its Table III speedups, replayed through the
    # cost model and labelled so they are never read as measurements.  The
    # graph500 target row is gone: RMAT is our own generator family, so it
    # is measured below instead of replayed.
    for name, d in PAPER_TABLE_III.items():
        if name == "graph500_s24":
            continue
        c = cca_cost_model(d["wedges"], d["triangles"])
        rows.append(dict(
            dataset=f"target(not run):{name}", vertices=d["vertices"],
            triangles=d["triangles"], wedges=d["wedges"],
            seq_hops=c.seq_hops, par_hops=c.par_hops, speedup=c.speedup,
        ))
    # measured on our generated graphs
    import jax.numpy as jnp
    for fam in ("scale_free", "powerlaw_cluster", "graph500"):
        src, dst, w, n = make_graph_family(fam, n_nodes, seed=seed)
        tri = triangle_count_exact(src, dst, n)
        tri_b = int(triangle_count_bitset(jnp.asarray(src),
                                          jnp.asarray(dst), n))
        assert tri == tri_b, (fam, tri, tri_b)
        deg = np.bincount(src, minlength=n)
        wdg = wedge_count(deg)
        c = cca_cost_model(wdg, tri)
        rows.append(dict(
            dataset=f"measured:{fam}", vertices=n, triangles=tri,
            wedges=wdg, seq_hops=c.seq_hops, par_hops=c.par_hops,
            speedup=c.speedup,
        ))
    # the powerlaw paper-comparison entry, measured for real: an RMAT
    # graph at the largest scale the bitset counter handles comfortably
    # (the chunked intersection is validated against the exact counter at
    # n_nodes above)
    if big_nodes and big_nodes > n_nodes:
        src, dst, w, n = make_graph_family("graph500", big_nodes, seed=seed)
        tri = _bitset_chunked(src, dst, n)
        deg = np.bincount(src, minlength=n)
        wdg = wedge_count(deg)
        c = cca_cost_model(wdg, tri)
        scale = int(np.log2(max(2, n)))
        rows.append(dict(
            dataset=f"measured:graph500_s{scale}", vertices=n,
            triangles=tri, wedges=wdg, seq_hops=c.seq_hops,
            par_hops=c.par_hops, speedup=c.speedup,
        ))
    return rows


def main():
    rows = run()
    print(f"{'dataset':26s} {'vertices':>10s} {'triangles':>11s} "
          f"{'wedges':>11s} {'speedup':>8s}")
    for r in rows:
        print(f"{r['dataset']:26s} {r['vertices']:10.3g} "
              f"{r['triangles']:11.3g} {r['wedges']:11.3g} "
              f"{r['speedup']:8.2f}")
    return rows


if __name__ == "__main__":
    main()
