"""Paper Table III + Figs 8-10: triangle counting and the CCA hops model.

Reproduces the paper's speculative analysis on its published dataset counts
(Twitter / WDC-2012 / Graph500-s24) AND re-derives the same table from
graphs we generate + count ourselves (exact + bitset counters).
"""

from __future__ import annotations

import numpy as np

from repro.core.generators import make_graph_family
from repro.core.triangles import (
    PAPER_TABLE_III,
    cca_cost_model,
    triangle_count_bitset,
    triangle_count_exact,
    wedge_count,
)


def run(n_nodes: int = 1200, seed: int = 0):
    rows = []
    # the paper's own published counts -> its Table III speedups; these
    # are TARGETS replayed through the cost model, not datasets this repo
    # has run — labelled so they are never read as measurements
    for name, d in PAPER_TABLE_III.items():
        c = cca_cost_model(d["wedges"], d["triangles"])
        rows.append(dict(
            dataset=f"target(not run):{name}", vertices=d["vertices"],
            triangles=d["triangles"], wedges=d["wedges"],
            seq_hops=c.seq_hops, par_hops=c.par_hops, speedup=c.speedup,
        ))
    # measured on our generated graphs
    import jax.numpy as jnp
    for fam in ("scale_free", "powerlaw_cluster", "graph500"):
        src, dst, w, n = make_graph_family(fam, n_nodes, seed=seed)
        tri = triangle_count_exact(src, dst, n)
        tri_b = int(triangle_count_bitset(jnp.asarray(src),
                                          jnp.asarray(dst), n))
        assert tri == tri_b, (fam, tri, tri_b)
        deg = np.bincount(src, minlength=n)
        wdg = wedge_count(deg)
        c = cca_cost_model(wdg, tri)
        rows.append(dict(
            dataset=f"measured:{fam}", vertices=n, triangles=tri,
            wedges=wdg, seq_hops=c.seq_hops, par_hops=c.par_hops,
            speedup=c.speedup,
        ))
    return rows


def main():
    rows = run()
    print(f"{'dataset':26s} {'vertices':>10s} {'triangles':>11s} "
          f"{'wedges':>11s} {'speedup':>8s}")
    for r in rows:
        print(f"{r['dataset']:26s} {r['vertices']:10.3g} "
              f"{r['triangles']:11.3g} {r['wedges']:11.3g} "
              f"{r['speedup']:8.2f}")
    return rows


if __name__ == "__main__":
    main()
